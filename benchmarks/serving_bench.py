"""Serving benchmarks: batched decode vs the seed's per-slot loop, bucketed
batched prefill vs per-prompt-length prefill, and chunked (step-based)
serving vs phase-based bucketed prefill.

Three comparisons, all written to ``BENCH_serving.json``:

* **decode**: the seed engine stepped B independent B=1 caches in a Python
  loop — B sequential memory-bound GEMV-shaped model calls per generated
  token. The engine advances all slots with ONE fused decode+sample call.
* **prefill (mixed-length workload)**: without bucketing, every distinct
  prompt length traces/compiles its own prefill; with the scheduler's
  power-of-two buckets, prompts are right-padded and prefilled in one jit'd
  batched call per bucket — at most ``n_buckets`` traces end-to-end.
* **chunked vs bucketed (latency)**: phase-based prefill stalls every
  active decode slot for a whole bucket; chunked mode feeds queued prompts
  through the decode-shaped path in fixed-size slices inside the SAME fused
  step, so TTFT of queued requests stops gating inter-token latency. The
  A/B runs the staggered-completion workload (mixed lengths AND mixed
  max_new) where slots free one at a time — the realistic mix where the
  phase-based convoy effect actually lands on ITL. TTFT and ITL p50/p95
  are reported per mode; the chunked steady state must trace at most 2
  step shapes (asserted — CI gate).
* **packed vs padded window**: the (B, W) window step pads every decode
  slot to W columns, so a step with decode slots + one in-flight chunk is
  mostly dead FLOPs. The token-packed step flattens the step's valid
  tokens into one dense pow-2-bucketed stream. Same staggered workload,
  fresh engines, compiles timed; per-step padding efficiency
  (valid / batch tokens, `EngineStats.packed_tokens / padded_tokens`) is
  recorded for both modes, the packed steady state must trace at most 3
  step shapes (CI gate), and in full mode the bench RAISES unless packed
  achieves >= 1.15x throughput or >= 1.15x better ITL p95 (the serving
  analogue of the kernel bench's int8 II gate).
* **fault tolerance (chaos)**: the staggered chunked workload re-run under
  a deterministic ``FaultPlan`` — ~10% of steps stalled 2ms, one injected
  step crash (watchdog rebuilds the core and recomputes live slots), one
  NaN-poisoned logits row (fused health check quarantines at most that one
  request). The bench records degraded vs fault-free throughput and in
  full mode RAISES if the ratio drops below 0.8x — recovery must cost
  recompute of in-flight work, not a collapse of the serving rate.
* **paged KV capacity**: a contiguous engine pins ``buffer_len`` tokens of
  KV per slot no matter how short the request; the paged engine spends the
  same HBM budget as a shared page pool, so short requests pin only the
  pages they touch. Peak concurrent requests at a fixed budget, paged vs
  contiguous — deterministic slot accounting, RAISES below 2x (smoke too).
* **multi-model gateway**: two same-architecture variants served through
  ONE stacked-alpha engine by the ``ServingGateway``. Two deterministic
  gates, both raising in smoke mode too: (a) the aggregate resident bytes
  of the pool (stacked pytree + registry ledger) must stay BELOW one
  dense-fp32 copy of the largest registered model — the paper's premise
  that what stays resident per model is the compressed alpha bank; (b)
  every request's token stream must be IDENTICAL to a dedicated
  single-model engine run of the same request (greedy and sampled) —
  cross-model batching is free of numerics drift. The cross-model step
  must also hold the single-model compile bound.
* **crash restart (durability)**: the staggered chunked workload with the
  write-ahead request journal armed, vs non-durable — the journal
  group-commits one fsync per engine step, so full mode RAISES below
  0.9x. Then a journaled run is abandoned mid-stream (unflushed tail
  discarded, the in-process kill -9) and a fresh engine recovers from the
  on-disk segments: zero lost requests and token streams identical to the
  fault-free run raise in EVERY mode; time-to-first-recovered-token is
  the reported restart-latency metric.
* **replica failover**: the multi-model workload on a 2-replica group with
  replica 0 killed mid-run by an injected step crash (``dead_after=1``).
  The health state machine must mark the replica DEAD and migrate its
  in-flight requests to the survivor via preempt-and-recompute. Raising
  gates in every mode: at least one failover, zero lost requests, and
  token streams identical to dedicated fault-free engines; full mode
  additionally requires >= 0.7x the throughput of a warm fault-free
  2-replica baseline.

``--hw`` threads any registered HW target (v5e/v5p/v6e/cpu) into the
mapper's execution planning (the model still *runs* on the host backend).
CPU numbers undersell the TPU story (no HBM wall on host), but the dispatch
and compile collapse alone is large at interactive batch sizes.
"""
from __future__ import annotations

import json
import os
import shutil
import sys
import tempfile
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import functools

import jax
import jax.numpy as jnp
import numpy as np

import dataclasses

from repro.configs import get_smoke_config
from repro.models import registry as R
from repro.serving import (FaultPlan, HealthPolicy, LLMEngine, ModelRegistry,
                           Request, RequestJournal, SamplingParams,
                           ServingGateway)
from repro.serving.model_registry import (alpha_bank_bytes, dense_fp32_bytes,
                                          make_alpha_variant, param_bytes)

MAX_STEP_SHAPES = 2      # chunked steady state: (B, chunk) window + (B, 1)
MAX_PACKED_STEP_SHAPES = 3   # packed: decode bucket + mixed bucket (+1 rare
                             # pow-2 overflow when prefill floors exceed the
                             # token budget)
PACKED_GATE = 1.15       # packed must beat the padded window by this factor
                         # on throughput OR ITL p95 (full mode; raises)
FAULT_GATE = 0.8         # chaos throughput floor vs fault-free (full mode):
                         # recovery = recompute, not collapse
PAGED_CAPACITY_GATE = 2.0    # paged KV must hold >= 2x the concurrent
                             # requests of contiguous slots at the same HBM
                             # budget (deterministic slot accounting — the
                             # gate applies in smoke mode too)
REPLICA_GATE = 0.7       # failover throughput floor vs a warm fault-free
                         # 2-replica run (full mode): killing one replica
                         # mid-run costs migration + recompute, not a
                         # collapse. Lost requests or stream divergence
                         # raise in EVERY mode.
CRASH_RESTART_GATE = 0.9     # journaled throughput floor vs non-durable
                             # (full mode): the write-ahead journal is an
                             # fsync per engine step (group commit), not a
                             # per-token stall. Lost requests or stream
                             # divergence after the mid-run kill raise in
                             # EVERY mode.
PAGE_SIZE = 16           # paged-capacity bench page size (tokens/page)
MM_RHO = 0.25            # multi-model bench compression ratio: M=2 resident
                         # banks at rho=0.25 keep the aggregate well under
                         # one dense copy (2 * 0.25 = half the linear bytes)
CHAOS_SPECS = ("delay:p=0.1,s=0.002",   # ~10% of steps stall 2ms
               "fail:step=5",           # one step crash -> rebuild + replay
               "nan:step=3,slot=0")     # one poisoned logits row


@functools.lru_cache(maxsize=4)
def _per_slot_step_fn(cfg):
    # shared across PerSlotEngine instances so recompilation never lands in a
    # timed pass (the batched engine shares its step the same way)
    return jax.jit(lambda p, c, t: R.serve_step(p, cfg, c, t))


class PerSlotEngine:
    """Faithful replica of the seed engine's decode loop (comparison target):
    one jit'd B=1 ``serve_step`` per active slot per token."""

    def __init__(self, params, cfg, *, batch_slots=4, buffer_len=256):
        self.params, self.cfg = params, cfg
        self.B, self.T = batch_slots, buffer_len
        self.queue: list = []
        self.slots = [None] * batch_slots
        self.slot_remaining = np.zeros(batch_slots, np.int32)
        self.caches = [R.init_cache(cfg, 1, buffer_len)
                       for _ in range(batch_slots)]
        self.tokens_out = 0
        self._step1 = _per_slot_step_fn(cfg)

    def submit(self, req):
        self.queue.append(req)

    def _fill(self):
        for i in range(self.B):
            if self.slots[i] is None and self.queue:
                req = self.queue.pop(0)
                prompt = jnp.asarray(req.prompt[None, :], jnp.int32)
                logits, cache = R.serve_prefill(
                    self.params, self.cfg, {"tokens": prompt}, self.T)
                self.caches[i] = cache
                req.out_tokens.append(int(jnp.argmax(logits[0])))
                self.slots[i] = req
                self.slot_remaining[i] = req.max_new_tokens - 1
                self.tokens_out += 1

    def step(self):
        self._fill()
        active = [i for i in range(self.B) if self.slots[i] is not None]
        for i in active:
            req = self.slots[i]
            tok = jnp.asarray([[req.out_tokens[-1]]], jnp.int32)
            logits, self.caches[i] = self._step1(self.params, self.caches[i],
                                                 tok)
            req.out_tokens.append(int(jnp.argmax(logits[0])))
            self.tokens_out += 1
            self.slot_remaining[i] -= 1
            if self.slot_remaining[i] <= 0:
                self.slots[i] = None
        return len(active)

    def drain(self, max_steps=10_000):
        for _ in range(max_steps):
            if self.step() == 0 and not self.queue:
                break


def _requests(cfg, n, rng):
    return [Request(rid, rng.integers(0, cfg.vocab, 8, dtype=np.int32),
                    max_new_tokens=16) for rid in range(n)]


def _mixed_requests(cfg, n, lo=4, hi=96):
    """Deterministic mixed-length workload: n prompts, lengths lo..hi."""
    lens = np.linspace(lo, hi, n).astype(int)
    rng = np.random.default_rng(2)
    return [Request(rid, rng.integers(0, cfg.vocab, int(L), dtype=np.int32),
                    max_new_tokens=8) for rid, L in enumerate(lens)]


def _staggered_requests(cfg, n, lo=4, hi=96):
    """Mixed lengths AND mixed generation budgets (4..19 tokens).

    Uniform ``max_new`` lets slots finish in lockstep, so phase-based
    prefill rarely coexists with decode and its convoy effect hides from
    ITL. Staggered completions are the realistic serving mix — slots free
    one at a time, every phase-based prefill stalls the other three active
    decoders — and are where chunked interleaving earns its keep.
    """
    lens = np.linspace(lo, hi, n).astype(int)
    rng = np.random.default_rng(2)
    return [Request(rid, rng.integers(0, cfg.vocab, int(L), dtype=np.int32),
                    max_new_tokens=4 + 3 * (rid % 6))
            for rid, L in enumerate(lens)]


def _latency(outputs) -> dict:
    """TTFT / inter-token-latency percentiles over finished requests."""
    ttfts = [o.ttft_s for o in outputs if o.ttft_s is not None]
    itls = [d for o in outputs for d in o.itls_s]
    pct = lambda xs, q: float(np.percentile(xs, q)) if xs else 0.0
    return {"ttft_p50_s": pct(ttfts, 50), "ttft_p95_s": pct(ttfts, 95),
            "itl_p50_s": pct(itls, 50), "itl_p95_s": pct(itls, 95)}


def run(print_fn=print, smoke: bool = False,
        json_path: str = "", hw: str = "v5e",
        chunk_size: int = 16, alpha_dtype: str = "") -> dict:
    # smoke runs land in a separate file so they never clobber the
    # full-mode perf trajectory (hw-suffixed: CI runs a small hw matrix);
    # full runs against a non-default hw are hw-suffixed too, so the
    # canonical BENCH_serving.json trajectory stays single-target (v5e)
    if not json_path:
        sfx = f"_{alpha_dtype}" if alpha_dtype else ""
        if smoke:
            json_path = f"BENCH_serving_smoke_{hw}{sfx}.json"
        else:
            json_path = (f"BENCH_serving{sfx}.json" if hw == "v5e"
                         else f"BENCH_serving_{hw}{sfx}.json")
    B = 4
    n_req = 4 if smoke else 8
    cfg = get_smoke_config("tinyllama_1_1b")
    if alpha_dtype:
        cfg = cfg.replace(ovsf=dataclasses.replace(
            cfg.ovsf, alpha_dtype=alpha_dtype))
    if not smoke:
        # Size the stack so decode is genuinely weight-read bound on the host
        # (weights >> LLC): this is the regime the batched rewrite targets —
        # the per-slot loop re-reads (and re-generates) every weight B times
        # per token, the batched step exactly once.
        cfg = cfg.replace(d_model=512, n_layers=4, d_ff=1536, vocab=4096,
                          n_heads=8, n_kv_heads=2, head_dim=64)
    params = R.model_init(jax.random.PRNGKey(0), cfg)

    def time_per_slot():
        eng = PerSlotEngine(params, cfg, batch_slots=B, buffer_len=64)
        for r in _requests(cfg, n_req, np.random.default_rng(0)):
            eng.submit(r)
        t0 = time.perf_counter()
        eng.drain()
        return eng.tokens_out, time.perf_counter() - t0

    def time_batched():
        eng = LLMEngine(params, cfg, batch_slots=B, buffer_len=64, hw=hw)
        for r in _requests(cfg, n_req, np.random.default_rng(0)):
            eng.submit(r)
        t0 = time.perf_counter()
        stats = eng.run_until_drained()
        return stats.tokens_out, time.perf_counter() - t0

    # warmup pass (compile both), then best-of-N timed passes (host-noise arm)
    time_per_slot()
    time_batched()
    n_pass = 1 if smoke else 2
    tps_a = max(tok / dt for tok, dt in (time_per_slot()
                                         for _ in range(n_pass)))
    tps_b = max(tok / dt for tok, dt in (time_batched()
                                         for _ in range(n_pass)))
    speedup = tps_b / tps_a
    print_fn(f"serving_bench,per_slot,B={B},{tps_a:.1f}tok/s")
    print_fn(f"serving_bench,batched,B={B},{tps_b:.1f}tok/s")
    print_fn(f"serving_bench,speedup,{speedup:.2f}x")

    # -- mixed-length workload: unbucketed vs bucketed vs chunked -----------
    # End-to-end on FRESH engines: prefill tracing/compilation is the cost
    # bucketing removes, so it stays inside the timed region. The decode
    # step fn is shared (lru by config) and warmed above.
    n_mixed = 8 if smoke else 16
    lo, hi = 4, (56 if smoke else 96)
    buf = 128

    def time_mixed(mode: str, reqs_fn=_mixed_requests):
        kw = {"bucketed_prefill": mode == "bucketed"}
        if mode == "chunked":
            kw = {"chunk_size": chunk_size}
        elif mode == "packed":
            kw = {"chunk_size": chunk_size, "packed": True}
        eng = LLMEngine(params, cfg, batch_slots=B, buffer_len=buf, hw=hw,
                        **kw)
        for r in reqs_fn(cfg, n_mixed, lo=lo, hi=hi):
            eng.submit(r)
        t0 = time.perf_counter()
        stats = eng.run_until_drained()
        return eng, stats, time.perf_counter() - t0

    eng_u, stats_u, dt_u = time_mixed("unbucketed")
    eng_b, stats_b, dt_b = time_mixed("bucketed")
    tps_u = stats_u.tokens_out / dt_u
    tps_bk = stats_b.tokens_out / dt_b
    bucketed_speedup = tps_bk / tps_u
    print_fn(f"serving_bench,mixed_unbucketed,B={B},n={n_mixed},"
             f"{tps_u:.1f}tok/s,compiles={stats_u.prefill_compiles}")
    print_fn(f"serving_bench,mixed_bucketed,B={B},n={n_mixed},"
             f"{tps_bk:.1f}tok/s,compiles={stats_b.prefill_compiles}")
    print_fn(f"serving_bench,bucketed_speedup,{bucketed_speedup:.2f}x")

    # -- chunked vs bucketed: staggered-completion latency A/B --------------
    eng_sb, stats_sb, dt_sb = time_mixed("bucketed", _staggered_requests)
    eng_c, stats_c, dt_c = time_mixed("chunked", _staggered_requests)
    tps_sb = stats_sb.tokens_out / dt_sb
    tps_c = stats_c.tokens_out / dt_c
    lat = {m: _latency(e.outputs())
           for m, e in (("unbucketed", eng_u), ("bucketed", eng_b),
                        ("bucketed_staggered", eng_sb), ("chunked", eng_c))}
    print_fn(f"serving_bench,staggered_bucketed,B={B},n={n_mixed},"
             f"{tps_sb:.1f}tok/s,compiles={stats_sb.prefill_compiles}")
    print_fn(f"serving_bench,staggered_chunked,B={B},n={n_mixed},"
             f"chunk={chunk_size},{tps_c:.1f}tok/s,"
             f"step_compiles={stats_c.step_compiles}")
    for m in ("bucketed_staggered", "chunked"):
        print_fn(f"serving_bench,latency_{m},"
                 f"ttft_p95={lat[m]['ttft_p95_s']*1e3:.1f}ms,"
                 f"itl_p50={lat[m]['itl_p50_s']*1e3:.1f}ms,"
                 f"itl_p95={lat[m]['itl_p95_s']*1e3:.1f}ms")
    itl_gain = (lat["bucketed_staggered"]["itl_p95_s"]
                / lat["chunked"]["itl_p95_s"]
                if lat["chunked"]["itl_p95_s"] > 0 else 0.0)
    print_fn(f"serving_bench,chunked_itl_p95_gain,{itl_gain:.2f}x,"
             f"throughput_ratio={tps_c / tps_sb:.2f}")

    # CI gate: the chunked steady state must stay on a bounded set of fused
    # step shapes regardless of the prompt-length mix.
    if stats_c.step_compiles > MAX_STEP_SHAPES:
        raise RuntimeError(
            f"chunked serving traced {stats_c.step_compiles} step shapes "
            f"(> {MAX_STEP_SHAPES}): the unified step is retracing")

    # -- packed vs padded window: same staggered workload, fresh engines ----
    eng_p, stats_p, dt_p = time_mixed("packed", _staggered_requests)
    tps_p = stats_p.tokens_out / dt_p
    lat["packed"] = _latency(eng_p.outputs())
    eff_window = stats_c.padding_efficiency
    eff_packed = stats_p.padding_efficiency
    packed_itl_gain = (lat["chunked"]["itl_p95_s"]
                       / lat["packed"]["itl_p95_s"]
                       if lat["packed"]["itl_p95_s"] > 0 else 0.0)
    packed_tps_ratio = tps_p / tps_c if tps_c > 0 else 0.0
    print_fn(f"serving_bench,staggered_packed,B={B},n={n_mixed},"
             f"chunk={chunk_size},{tps_p:.1f}tok/s,"
             f"step_compiles={stats_p.step_compiles},"
             f"efficiency={eff_packed:.2f}")
    print_fn(f"serving_bench,padding_efficiency,"
             f"window={eff_window:.2f},packed={eff_packed:.2f}")
    print_fn(f"serving_bench,packed_vs_window,"
             f"throughput_ratio={packed_tps_ratio:.2f},"
             f"itl_p95_gain={packed_itl_gain:.2f}x")

    # CI gate: the packed steady state must also stay shape-bounded.
    if stats_p.step_compiles > MAX_PACKED_STEP_SHAPES:
        raise RuntimeError(
            f"packed serving traced {stats_p.step_compiles} step shapes "
            f"(> {MAX_PACKED_STEP_SHAPES}): the pow-2 token bucketing is "
            f"leaking shapes")
    # Perf gate (full mode only — smoke wall-clock on shared CI runners is
    # noise): packed must beat the padded window on throughput OR ITL p95.
    if not smoke and packed_tps_ratio < PACKED_GATE \
            and packed_itl_gain < PACKED_GATE:
        raise RuntimeError(
            f"packed step regressed: {packed_tps_ratio:.2f}x throughput / "
            f"{packed_itl_gain:.2f}x ITL p95 vs the padded window (need "
            f">= {PACKED_GATE}x on one)")

    # -- fault tolerance: chunked staggered workload under injected chaos --
    # Same workload and mode as the chunked run above, plus a deterministic
    # FaultPlan: ~10% of steps delayed, one step crash (engine watchdog
    # rebuilds the core and recomputes live slots), one NaN row (fused
    # health check quarantines at most one request). The fault-free
    # baseline is re-timed WARM through the same harness — the earlier
    # tps_c run pays compiles inside its timed region, which would make
    # the degradation gate vacuous. Recovery must be recompute-cheap.
    plan = FaultPlan.parse(CHAOS_SPECS, seed=0)

    def time_chaos(faults):
        eng = LLMEngine(params, cfg, batch_slots=B, buffer_len=buf, hw=hw,
                        chunk_size=chunk_size, faults=faults)
        for r in _staggered_requests(cfg, n_mixed, lo=lo, hi=hi):
            eng.submit(r)
        t0 = time.perf_counter()
        stats = eng.run_until_drained()
        return eng, stats, time.perf_counter() - t0

    _, stats_w, dt_w = time_chaos(None)        # warm fault-free baseline
    tps_w = stats_w.tokens_out / dt_w
    eng_f, stats_f, dt_f = time_chaos(plan)
    tps_f = stats_f.tokens_out / dt_f
    chaos_ratio = tps_f / tps_w if tps_w > 0 else 0.0
    print_fn(f"serving_bench,chaos,B={B},n={n_mixed},{tps_f:.1f}tok/s,"
             f"recoveries={stats_f.recoveries},errors={stats_f.errors},"
             f"completed={stats_f.completed}")
    print_fn(f"serving_bench,chaos_vs_faultfree,{chaos_ratio:.2f}x")
    if stats_f.recoveries < 1:
        raise RuntimeError(
            "chaos bench: the injected step crash produced no recovery — "
            "the engine watchdog did not fire")
    if len(eng_f.outputs()) != n_mixed:
        raise RuntimeError(
            f"chaos bench lost requests: {len(eng_f.outputs())}/{n_mixed} "
            f"reached a terminal state")
    if not smoke and chaos_ratio < FAULT_GATE:
        raise RuntimeError(
            f"chaos throughput collapsed: {chaos_ratio:.2f}x the fault-free "
            f"baseline under ~10% injected step faults (need "
            f">= {FAULT_GATE}x)")

    # -- paged KV capacity: concurrency at a fixed HBM budget ---------------
    # A contiguous engine pins buffer_len tokens of KV per slot no matter
    # how short the request, so a kv-budget of B*buf tokens caps concurrency
    # at B. The paged engine spends the SAME budget as a shared page pool:
    # short requests pin only the pages they touch, so many more of them
    # decode concurrently. Short-request workload (1 page per request
    # lifetime) on 4x the slots; peak simultaneously-occupied slots is the
    # measured capacity. Deterministic, so the >= 2x gate raises in smoke
    # mode too.
    kv_budget_tokens = B * buf
    paged_slots = 4 * B

    def paged_capacity():
        eng = LLMEngine(params, cfg, batch_slots=paged_slots, buffer_len=buf,
                        hw=hw, chunk_size=chunk_size, paged=True,
                        page_size=PAGE_SIZE,
                        kv_pages=kv_budget_tokens // PAGE_SIZE)
        rng = np.random.default_rng(3)
        for rid in range(paged_slots):
            # 4 prompt + 12 generated = 16 tokens: one PAGE_SIZE page each
            eng.submit(Request(rid,
                               rng.integers(0, cfg.vocab, 4, dtype=np.int32),
                               max_new_tokens=12))
        peak = 0
        while True:
            remaining = eng.step()
            peak = max(peak, sum(s is not None for s in eng.slots))
            if remaining == 0:
                break
        return eng, eng.stats, peak

    eng_pc, stats_pc, paged_peak = paged_capacity()
    contiguous_cap = kv_budget_tokens // buf    # == B by construction
    capacity_ratio = paged_peak / contiguous_cap
    print_fn(f"serving_bench,paged_capacity,budget={kv_budget_tokens}tok,"
             f"contiguous={contiguous_cap},paged_peak={paged_peak},"
             f"ratio={capacity_ratio:.2f}x,"
             f"kv_util={stats_pc.kv_utilization:.2f}")
    if stats_pc.completed != paged_slots:
        raise RuntimeError(
            f"paged capacity bench: {stats_pc.completed}/{paged_slots} "
            f"requests completed")
    if eng_pc.core.pager.used_pages != 0:
        raise RuntimeError("paged capacity bench leaked pages: "
                           f"{eng_pc.core.pager.used_pages} still granted "
                           f"after drain")
    if capacity_ratio < PAGED_CAPACITY_GATE:
        raise RuntimeError(
            f"paged KV capacity regressed: {capacity_ratio:.2f}x the "
            f"contiguous concurrency at a {kv_budget_tokens}-token budget "
            f"(need >= {PAGED_CAPACITY_GATE}x)")

    # -- multi-model gateway: resident banks + cross-config batching --------
    # Spectral-pinned config: the stacked multi kernel routes through the
    # spectral identity, which is bit-exact against the single-model
    # spectral path (the dedicated baselines below) — the identity gate
    # compares raw token streams, so the baselines must share the path.
    mm_cfg = cfg.replace(ovsf=dataclasses.replace(
        cfg.ovsf, rho=MM_RHO, exec_path="spectral", alpha_dtype=""))
    mm_base = R.model_init(jax.random.PRNGKey(0), mm_cfg)
    mm_var = make_alpha_variant(mm_base, seed=1)
    n_mm = 6 if smoke else 12

    def mm_requests():
        rng = np.random.default_rng(5)
        reqs = []
        for rid in range(n_mm):
            sp = (SamplingParams() if rid % 3 else
                  SamplingParams(temperature=0.8, top_k=20, seed=rid))
            reqs.append(Request(
                rid, rng.integers(0, mm_cfg.vocab, 4 + 2 * rid,
                                  dtype=np.int32),
                max_new_tokens=6 + rid % 4, sampling=sp,
                model="tl-a" if rid % 2 == 0 else "tl-b"))
        return reqs

    reg = ModelRegistry()
    reg.register("tl-a", mm_cfg, lambda: mm_base)
    reg.register("tl-b", mm_cfg, lambda: mm_var)
    gw = ServingGateway(reg, batch_slots=B, buffer_len=buf,
                        chunk_size=chunk_size, hw=hw)
    for r in mm_requests():
        gw.add_request(r)
    t0 = time.perf_counter()
    gw.run_until_drained()
    dt_mm = time.perf_counter() - t0
    mm_outs = {o.rid: tuple(o.tokens) for o in gw.outputs()}
    tps_mm = sum(len(t) for t in mm_outs.values()) / dt_mm

    dd_outs = {}
    dd_tokens, dd_dt = 0, 0.0
    for model, p_ in (("tl-a", mm_base), ("tl-b", mm_var)):
        eng = LLMEngine(p_, mm_cfg, batch_slots=B, buffer_len=buf,
                        chunk_size=chunk_size, hw=hw, use_mapper=False)
        for r in mm_requests():
            if r.model == model:
                eng.add_request(r)
        t0 = time.perf_counter()
        stats_d = eng.run_until_drained()
        dd_dt += time.perf_counter() - t0
        dd_tokens += stats_d.tokens_out
        for o in eng.outputs():
            dd_outs[o.rid] = tuple(o.tokens)
    tps_dd = dd_tokens / dd_dt

    mm_eng = gw.engine_for("tl-a")
    resident = max(gw.resident_bytes(), reg.resident_bytes())
    dense_largest = max(dense_fp32_bytes(e.cfg)
                        for e in reg.entries.values())
    residency_ratio = resident / dense_largest
    mismatches = [rid for rid in mm_outs if mm_outs[rid] != dd_outs.get(rid)]
    print_fn(f"serving_bench,multi_model,models=2,n={n_mm},"
             f"{tps_mm:.1f}tok/s,dedicated={tps_dd:.1f}tok/s,"
             f"step_shapes={len(mm_eng.core.step_shapes)}")
    print_fn(f"serving_bench,multi_model_residency,resident={resident},"
             f"dense_fp32_largest={dense_largest},"
             f"ratio={residency_ratio:.2f}")
    # Gate (a): the pool's resident bytes must undercut ONE dense copy of
    # the largest model — deterministic byte accounting, raises in smoke.
    if resident >= dense_largest:
        raise RuntimeError(
            f"multi-model residency gate: {resident} resident bytes for "
            f"{len(reg.names())} models >= one dense-fp32 copy of the "
            f"largest ({dense_largest}) — the alpha banks stopped paying "
            f"for themselves")
    # Gate (b): token streams must be identical to dedicated engines.
    if mismatches:
        raise RuntimeError(
            f"multi-model identity gate: requests {mismatches} diverged "
            f"from their dedicated single-model engines")
    # The cross-model step shares the single-model compile bound.
    if len(mm_eng.core.step_shapes) > MAX_STEP_SHAPES:
        raise RuntimeError(
            f"multi-model step traced {len(mm_eng.core.step_shapes)} "
            f"shapes (> {MAX_STEP_SHAPES}): variant routing is retracing")

    # -- replica failover: kill one of two replicas mid-run -----------------
    # Same multi-model workload on a 2-replica group. The faulted run kills
    # replica 0 with an injected step crash (dead_after=1: the first
    # incident is terminal) and must migrate its in-flight requests to the
    # survivor via preempt-and-recompute. Three always-on gates — at least
    # one failover fired, zero lost requests, token streams identical to
    # the dedicated fault-free engines — plus a full-mode throughput floor
    # against a WARM fault-free 2-replica baseline (the first run below
    # pays any residual compiles so the timed pair compares steady state).
    def time_fleet(faults):
        reg_f = ModelRegistry()
        reg_f.register("tl-a", mm_cfg, lambda: mm_base)
        reg_f.register("tl-b", mm_cfg, lambda: mm_var)
        gw_f = ServingGateway(
            reg_f, batch_slots=B, buffer_len=buf, chunk_size=chunk_size,
            hw=hw, faults=faults, replicas=2,
            health=HealthPolicy(degraded_after=1, dead_after=1))
        for r in mm_requests():
            gw_f.add_request(r)
        t0 = time.perf_counter()
        gw_f.run_until_drained()
        return gw_f, time.perf_counter() - t0

    time_fleet(None)                              # warm-up
    _, dt_rw = time_fleet(None)                   # warm fault-free baseline
    kill = {"tl-a": FaultPlan.parse(["fail:step=2"], seed=0)}
    gw_k, dt_rk = time_fleet(kill)
    fo_outs = {o.rid: tuple(o.tokens) for o in gw_k.outputs()}
    tps_rw = sum(len(t) for t in dd_outs.values()) / dt_rw
    tps_rk = sum(len(t) for t in fo_outs.values()) / dt_rk
    failover_ratio = tps_rk / tps_rw if tps_rw > 0 else 0.0
    fo_lost = [rid for rid in range(n_mm) if rid not in fo_outs]
    fo_diverged = [rid for rid in fo_outs
                   if fo_outs[rid] != dd_outs.get(rid)]
    print_fn(f"serving_bench,replica_failover,replicas=2,n={n_mm},"
             f"{tps_rk:.1f}tok/s,faultfree={tps_rw:.1f}tok/s,"
             f"failovers={gw_k.stats.failovers},"
             f"migrated={gw_k.stats.failover_requests}")
    print_fn(f"serving_bench,replica_failover_vs_faultfree,"
             f"{failover_ratio:.2f}x")
    if gw_k.stats.failovers < 1:
        raise RuntimeError(
            "replica-failover bench: the injected replica kill produced no "
            "failover — the health state machine did not fire")
    if fo_lost:
        raise RuntimeError(
            f"replica-failover bench lost requests {fo_lost}: every "
            f"in-flight request must survive a replica death")
    if fo_diverged:
        raise RuntimeError(
            f"replica-failover bench: requests {fo_diverged} diverged from "
            f"their dedicated fault-free engines — migration must be "
            f"token-identical")
    if not smoke and failover_ratio < REPLICA_GATE:
        raise RuntimeError(
            f"replica-failover throughput collapsed: {failover_ratio:.2f}x "
            f"the warm fault-free 2-replica baseline (need "
            f">= {REPLICA_GATE}x)")

    # -- crash restart: write-ahead journal overhead + recovery -------------
    # (a) Durable vs non-durable throughput on the staggered chunked
    # workload: the journal fsyncs once per engine step (group commit), so
    # full mode RAISES below 0.9x. (b) A journaled run is abandoned
    # mid-stream with its unflushed tail discarded — the in-process
    # equivalent of kill -9 — and a fresh engine rebuilt from the on-disk
    # segments must finish every request with token streams IDENTICAL to
    # the non-durable baseline (both gates raise in every mode);
    # time-to-first-recovered-token (journal replay + engine rebuild +
    # compile + steps until a recovered request emits a NEW token) is the
    # reported restart-latency metric.
    def time_journal(journal):
        eng = LLMEngine(params, cfg, batch_slots=B, buffer_len=buf, hw=hw,
                        chunk_size=chunk_size, journal=journal)
        for r in _staggered_requests(cfg, n_mixed, lo=lo, hi=hi):
            eng.submit(r)
        t0 = time.perf_counter()
        stats = eng.run_until_drained()
        return eng, stats, time.perf_counter() - t0

    jroot = tempfile.mkdtemp(prefix="serving_bench_journal_")
    try:
        eng_nd, stats_nd, dt_nd = time_journal(None)   # warm (post-chaos)
        tps_nd = stats_nd.tokens_out / dt_nd
        nd_outs = {o.rid: tuple(o.tokens) for o in eng_nd.outputs()}
        eng_jd, stats_jd, dt_jd = time_journal(
            RequestJournal(os.path.join(jroot, "overhead")))
        tps_jd = stats_jd.tokens_out / dt_jd
        durable_ratio = tps_jd / tps_nd if tps_nd > 0 else 0.0
        print_fn(f"serving_bench,crash_restart_overhead,"
                 f"durable={tps_jd:.1f}tok/s,nondurable={tps_nd:.1f}tok/s,"
                 f"ratio={durable_ratio:.2f}x")

        kdir = os.path.join(jroot, "kill")
        jk = RequestJournal(kdir)
        eng_k = LLMEngine(params, cfg, batch_slots=B, buffer_len=buf, hw=hw,
                          chunk_size=chunk_size, journal=jk)
        for r in _staggered_requests(cfg, n_mixed, lo=lo, hi=hi):
            eng_k.submit(r)
        kill_after = 4
        for _ in range(kill_after):
            if eng_k.step() == 0:
                break
        jk.close()      # abandon engine + journal: the unflushed tail is
        del eng_k       # lost, exactly as under kill -9

        t0 = time.perf_counter()
        jr = RequestJournal(kdir)
        eng_r = LLMEngine(params, cfg, batch_slots=B, buffer_len=buf, hw=hw,
                          chunk_size=chunk_size, journal=jr)
        recovered = eng_r.recover_from_journal()
        base = {r.rid: len(r.out_tokens) for r in recovered}
        ttfrt = None
        while True:
            remaining = eng_r.step()
            if ttfrt is None and any(
                    len(r.out_tokens) > base[r.rid] for r in recovered):
                ttfrt = time.perf_counter() - t0
            if remaining == 0:
                break
        rec_outs = {rid: tuple(e.tokens) for rid, e in jr.entries.items()}
        cr_lost = [rid for rid in nd_outs
                   if not (rid in jr.entries and jr.entries[rid].done)]
        cr_diverged = [rid for rid in nd_outs
                       if rec_outs.get(rid) != nd_outs[rid]]
        print_fn(f"serving_bench,crash_restart,killed_after={kill_after},"
                 f"recovered={len(recovered)},"
                 f"ttfrt={ttfrt if ttfrt is not None else -1:.3f}s")
        if not recovered:
            raise RuntimeError(
                "crash-restart bench: the mid-run kill left no live "
                "journaled requests to recover — the kill landed after "
                "drain, the bench proves nothing")
        if cr_lost:
            raise RuntimeError(
                f"crash-restart bench lost requests {cr_lost}: every "
                f"journaled request must reach a terminal state exactly "
                f"once across the restart")
        if cr_diverged:
            raise RuntimeError(
                f"crash-restart bench: requests {cr_diverged} diverged "
                f"from the fault-free run — journal recovery must be "
                f"token-identical")
        if not smoke and durable_ratio < CRASH_RESTART_GATE:
            raise RuntimeError(
                f"write-ahead journaling costs too much: {durable_ratio:.2f}"
                f"x the non-durable throughput (need >= "
                f"{CRASH_RESTART_GATE}x — the journal must group-commit "
                f"per step, not stall per token)")
    finally:
        shutil.rmtree(jroot, ignore_errors=True)

    result = {"bench": "serving", "smoke": smoke, "batch_slots": B,
              "model": cfg.name, "backend": jax.default_backend(), "hw": hw,
              "alpha_dtype": alpha_dtype,
              "per_slot_tok_s": tps_a, "batched_tok_s": tps_b,
              "speedup": speedup,
              "bucketed_prefill": {
                  "n_requests": n_mixed, "prompt_lens": f"mixed {lo}..{hi}",
                  "unbucketed_tok_s": tps_u, "bucketed_tok_s": tps_bk,
                  "speedup": bucketed_speedup,
                  "unbucketed_prefill_compiles": stats_u.prefill_compiles,
                  "bucketed_prefill_compiles": stats_b.prefill_compiles,
                  "bucketed_prefill_s": stats_b.prefill_s,
                  "unbucketed_prefill_s": stats_u.prefill_s},
              "chunked_prefill": {
                  "n_requests": n_mixed,
                  "prompt_lens": f"mixed {lo}..{hi}",
                  "max_new": "staggered 4..19",
                  "chunk_size": chunk_size,
                  "chunked_tok_s": tps_c, "bucketed_tok_s": tps_sb,
                  "throughput_ratio_vs_bucketed": tps_c / tps_sb,
                  "itl_p95_gain_vs_bucketed": itl_gain,
                  "step_compiles": stats_c.step_compiles,
                  "chunk_tokens": stats_c.chunk_tokens},
              "packed_step": {
                  "n_requests": n_mixed,
                  "prompt_lens": f"mixed {lo}..{hi}",
                  "max_new": "staggered 4..19",
                  "chunk_size": chunk_size,
                  "packed_tok_s": tps_p, "window_tok_s": tps_c,
                  "throughput_ratio_vs_window": packed_tps_ratio,
                  "itl_p95_gain_vs_window": packed_itl_gain,
                  "padding_efficiency_window": eff_window,
                  "padding_efficiency_packed": eff_packed,
                  "window_valid_tokens": stats_c.packed_tokens,
                  "window_batch_tokens": stats_c.padded_tokens,
                  "packed_valid_tokens": stats_p.packed_tokens,
                  "packed_batch_tokens": stats_p.padded_tokens,
                  "step_compiles": stats_p.step_compiles},
              "fault_tolerance": {
                  "n_requests": n_mixed,
                  "faults": list(CHAOS_SPECS),
                  "chaos_tok_s": tps_f, "fault_free_tok_s": tps_w,
                  "throughput_ratio_vs_fault_free": chaos_ratio,
                  "recoveries": stats_f.recoveries,
                  "errors": stats_f.errors,
                  "stalls": stats_f.stalls,
                  "completed": stats_f.completed},
              "paged_capacity": {
                  "kv_budget_tokens": kv_budget_tokens,
                  "page_size": PAGE_SIZE,
                  "paged_slots": paged_slots,
                  "contiguous_concurrency": contiguous_cap,
                  "paged_peak_concurrency": paged_peak,
                  "capacity_ratio": capacity_ratio,
                  "kv_pages_total": stats_pc.kv_pages_total,
                  "kv_pages_peak": stats_pc.kv_pages_used,
                  "kv_utilization": stats_pc.kv_utilization,
                  "completed": stats_pc.completed},
              "multi_model": {
                  "n_models": len(reg.names()),
                  "n_requests": n_mm,
                  "rho": MM_RHO,
                  "gateway_tok_s": tps_mm,
                  "dedicated_tok_s": tps_dd,
                  "consolidation_ratio": tps_mm / tps_dd if tps_dd else 0.0,
                  "resident_bytes": resident,
                  "alpha_bank_bytes": (alpha_bank_bytes(mm_base)
                                       + alpha_bank_bytes(mm_var)),
                  "dense_fp32_largest_bytes": dense_largest,
                  "residency_ratio": residency_ratio,
                  "streams_identical": not mismatches,
                  "step_shapes": len(mm_eng.core.step_shapes),
                  "stacked_param_bytes": param_bytes(mm_eng.params)},
              "replica_failover": {
                  "replicas": 2,
                  "n_requests": n_mm,
                  "faults": ["fail:step=2"],
                  "failover_tok_s": tps_rk,
                  "fault_free_tok_s": tps_rw,
                  "throughput_ratio_vs_fault_free": failover_ratio,
                  "failovers": gw_k.stats.failovers,
                  "migrated_requests": gw_k.stats.failover_requests,
                  "replicas_dead": gw_k.stats.replicas_dead,
                  "lost_requests": len(fo_lost),
                  "streams_identical": not fo_diverged},
              "crash_restart": {
                  "n_requests": n_mixed,
                  "killed_after_steps": kill_after,
                  "durable_tok_s": tps_jd,
                  "non_durable_tok_s": tps_nd,
                  "throughput_ratio_vs_non_durable": durable_ratio,
                  "recovered_requests": len(recovered),
                  "time_to_first_recovered_token_s": ttfrt,
                  "lost_requests": len(cr_lost),
                  "streams_identical": not cr_diverged},
              "latency": lat}
    if json_path:
        # atomic: a crash mid-write must never leave a torn BENCH_*.json
        # (the reanalyze/trajectory tooling trusts these files blindly)
        from repro.checkpoint.ckpt import atomic_write_json
        atomic_write_json(json_path, result, indent=2)
        print_fn(f"serving_bench,json,{json_path}")
    return result


if __name__ == "__main__":
    import argparse

    from repro.serving import hw_names

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--hw", default="v5e", choices=list(hw_names()))
    ap.add_argument("--chunk-size", type=int, default=16)
    ap.add_argument("--alpha-dtype", default="", choices=["", "int8", "int4"],
                    help="serve with quantised alpha storage")
    a = ap.parse_args()
    run(smoke=a.smoke, hw=a.hw, chunk_size=a.chunk_size,
        alpha_dtype=a.alpha_dtype)
