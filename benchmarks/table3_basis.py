"""Paper Table 3: basis-selection strategy (sequential vs iterative drop) and
3x3-from-4x4 extraction (crop vs adaptive pooling).

Offline proxy for the accuracy columns (no ImageNet/CIFAR in this container):
 1. reconstruction error of trained-filter statistics under each combo —
    iterative is L2-optimal so it must dominate sequential (the paper's
    consistent finding);
 2. a small synthetic classification task trained with each combo for a few
    steps (same protocol for all four) — relative ordering of losses.
"""
from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ovsf
from repro.models.cnn import CNNConfig, cnn_init, cnn_loss


def reconstruction_err(strategy: str, extract: str, rho: float,
                       key) -> float:
    """Spatial-mode reconstruction error on a bank of correlated filters."""
    cin, cout, k0 = 32, 64, 4
    base = jax.random.normal(key, (cout, cin, k0, k0))
    # make filters smooth-ish (real CNN filters are low-frequency-biased)
    sm = jnp.array([[0.25, 0.5, 0.25]])
    smooth = base + 0.5 * jnp.roll(base, 1, -1) + 0.5 * jnp.roll(base, 1, -2)
    target = ovsf.extract_kxk(smooth, 3, "crop")          # "true" 3x3 filters
    al = ovsf.regress_alphas(smooth.reshape(cout, -1))
    idx, kept = ovsf.select_basis(al, rho, strategy)      # type: ignore[arg-type]
    rec4 = ovsf.reconstruct(kept, idx, cin * k0 * k0).reshape(cout, cin, k0, k0)
    rec3 = ovsf.extract_kxk(rec4, 3, extract)             # type: ignore[arg-type]
    return float(jnp.linalg.norm(rec3 - target)
                 / jnp.linalg.norm(target))


def synthetic_task_loss(strategy: str, extract: str, rho: float,
                        steps: int = 8) -> float:
    cfg = CNNConfig(name="t", depth="resnet18", num_classes=10, in_hw=24,
                    width_mult=0.25, ovsf_enable=True, ovsf_mode="spatial",
                    extract=extract, strategy=strategy,
                    block_rhos=(1.0, rho, rho, rho))
    key = jax.random.PRNGKey(0)
    params, state = cnn_init(key, cfg)
    x = jax.random.normal(key, (8, 24, 24, 3))
    labels = jnp.arange(8) % 10

    grad_fn = jax.jit(jax.value_and_grad(
        lambda p, s: cnn_loss(p, s, cfg, x, labels)[0], allow_int=True))
    lr = 0.05
    for _ in range(steps):
        loss, g = grad_fn(params, state)
        params = jax.tree_util.tree_map(
            lambda p, gg: p - lr * gg
            if jnp.issubdtype(p.dtype, jnp.floating) else p, params, g)
    return float(loss)


def run(print_fn=print) -> list[dict]:
    rows = []
    key = jax.random.PRNGKey(42)
    for rho, tag in ((0.5, "OVSF50"), (0.25, "OVSF25")):
        errs = {}
        for strat in ("sequential", "iterative"):
            for ext in ("crop", "adaptive"):
                e = reconstruction_err(strat, ext, rho, key)
                l = synthetic_task_loss(strat, ext, rho)
                errs[(strat, ext)] = e
                rows.append(dict(rho=rho, strategy=strat, extract=ext,
                                 rec_err=e, task_loss=l))
                print_fn(f"table3,{tag},{strat},{ext},rec_err={e:.4f},"
                         f"task_loss={l:.3f}")
        ok = (errs[("iterative", "crop")] <= errs[("sequential", "crop")]
              and errs[("iterative", "adaptive")]
              <= errs[("sequential", "adaptive")])
        print_fn(f"table3,{tag},CHECK iterative<=sequential: {ok}")
    return rows


if __name__ == "__main__":
    run()
