"""Kernel microbench: OVSF execution paths vs dense GEMM.

CPU wall-clock is NOT the TPU story (interpret-mode Pallas is a correctness
tool); the meaningful output here is (a) jnp-path relative timings on CPU as
a sanity signal and (b) the analytical per-path roofline terms for a
representative decode-shaped GEMM on v5e constants.
"""
from __future__ import annotations

import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ovsf
from repro.hwmodel import perf_model as pm
from repro.kernels import ops


def _time(fn, *args, reps=5) -> float:
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6


def run(print_fn=print) -> list[dict]:
    rows = []
    M, d_in, d_out, rho = 16, 2048, 2048, 0.5
    key = jax.random.PRNGKey(0)
    W = jax.random.normal(key, (d_in, d_out)) * 0.02
    x = jax.random.normal(key, (M, d_in))
    spec = ovsf.OVSFSpec(d_in, d_out, rho=rho, seg=16)
    p = ovsf.compress_matrix(W, spec)

    dense = jax.jit(lambda a, b: a @ b)
    spectral = jax.jit(lambda a, al, ix: ops.ovsf_matmul(
        a, al, ix, path="spectral", use_pallas=False))
    mat = jax.jit(lambda a, al, ix: ops.ovsf_matmul(
        a, al, ix, path="materialize", use_pallas=False))

    t_dense = _time(dense, x, W)
    t_spec = _time(spectral, x, p["alphas"], p["idx"])
    t_mat = _time(mat, x, p["alphas"], p["idx"])
    for name, t in [("dense", t_dense), ("ovsf_spectral", t_spec),
                    ("ovsf_materialize", t_mat)]:
        print_fn(f"kernel_bench,cpu_wall,{name},{t:.1f}us")
        rows.append(dict(kind="cpu", name=name, us=t))

    # analytical decode-shape roofline per path (v5e)
    for path in ("materialize", "fused", "spectral"):
        l = pm.GemmLayer("bench", M=8, d_in=4096, d_out=4096, rho=0.5,
                         ovsf=True, exec_path=path, seg=16)
        t = pm.layer_timing(l)
        print_fn(f"kernel_bench,v5e_model,{path},ii={t.ii*1e6:.2f}us,"
                 f"bound={t.bound},mem_w={t.t_mem_w*1e6:.2f}us,"
                 f"wgen={t.t_wgen*1e6:.2f}us,eng={t.t_eng*1e6:.2f}us")
        rows.append(dict(kind="v5e", name=path, ii_us=t.ii * 1e6,
                         bound=t.bound))
    ld = pm.GemmLayer("dense", M=8, d_in=4096, d_out=4096)
    t = pm.layer_timing(ld)
    print_fn(f"kernel_bench,v5e_model,dense,ii={t.ii*1e6:.2f}us,bound={t.bound}")
    rows.append(dict(kind="v5e", name="dense", ii_us=t.ii * 1e6, bound=t.bound))
    return rows


if __name__ == "__main__":
    run()
