"""Kernel microbench: OVSF execution paths vs dense GEMM.

CPU wall-clock is NOT the TPU story (interpret-mode Pallas is a correctness
tool); the meaningful output here is (a) jnp-path relative timings on CPU as
a sanity signal and (b) the analytical per-path roofline terms for a
representative decode-shaped GEMM on v5e constants.

Emits a machine-readable ``BENCH_kernels.json`` next to the CSV lines so the
perf trajectory is comparable across PRs.
"""
from __future__ import annotations

import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ovsf
from repro.hwmodel import perf_model as pm
from repro.kernels import ops


def _time(fn, *args, reps=5) -> float:
    """Median-free mean wall time in us, after exactly one warmup call."""
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6


def run(print_fn=print, smoke: bool = False,
        json_path: str = "", alpha_dtype: str = "") -> list[dict]:
    """``alpha_dtype`` ("int8"/"int4") additionally gates that dtype's modeled
    fused II strictly below fused-fp (int8 is always gated — the bench FAILS,
    for CI, if quantising the alpha stream stops paying on v5e)."""
    json_path = json_path or (
        "BENCH_kernels_smoke.json" if smoke else "BENCH_kernels.json")
    rows = []
    if smoke:
        M, d_in, d_out, rho, reps = 8, 512, 512, 0.5, 2
    else:
        M, d_in, d_out, rho, reps = 16, 2048, 2048, 0.5, 5
    key = jax.random.PRNGKey(0)
    W = jax.random.normal(key, (d_in, d_out)) * 0.02
    x = jax.random.normal(key, (M, d_in))
    spec = ovsf.OVSFSpec(d_in, d_out, rho=rho, seg=16)
    p = ovsf.compress_matrix(W, spec)

    dense = jax.jit(lambda a, b: a @ b)
    spectral = jax.jit(lambda a, al, ix: ops.ovsf_matmul(
        a, al, ix, path="spectral", use_pallas=False))
    mat = jax.jit(lambda a, al, ix: ops.ovsf_matmul(
        a, al, ix, path="materialize", use_pallas=False))
    fused = jax.jit(lambda a, al, ix: ops.ovsf_matmul(
        a, al, ix, path="fused", use_pallas=False))

    t_dense = _time(dense, x, W, reps=reps)
    t_spec = _time(spectral, x, p["alphas"], p["idx"], reps=reps)
    t_mat = _time(mat, x, p["alphas"], p["idx"], reps=reps)
    t_fused = _time(fused, x, p["alphas"], p["idx"], reps=reps)
    # off-TPU the fused path runs the f32 decompress-then-GEMM oracle, not
    # the TiWGen kernel — label it _ref so trajectories don't misread it
    ref_sfx = "" if ops.on_tpu() else "_ref"
    for name, t in [("dense", t_dense), ("ovsf_spectral", t_spec),
                    ("ovsf_materialize", t_mat),
                    (f"ovsf_fused{ref_sfx}", t_fused)]:
        print_fn(f"kernel_bench,cpu_wall,{name},{t:.1f}us")
        rows.append(dict(kind="cpu", name=name, us=t))

    # quantised alpha storage: measured CPU walls for the same shape
    for dt in ("int8", "int4"):
        pq = ovsf.quantize_params(p, dt)
        al, sc, _ = ovsf.alpha_params(pq)
        fused_q = jax.jit(lambda a, q, s, ix, dt=dt: ops.ovsf_matmul(
            a, q, ix, path="fused", use_pallas=False,
            alpha_scale=s, alpha_dtype=dt))
        mat_q = jax.jit(lambda a, q, s, ix, dt=dt: ops.ovsf_matmul(
            a, q, ix, path="materialize", use_pallas=False,
            alpha_scale=s, alpha_dtype=dt))
        for name, t in [
                (f"ovsf_fused_{dt}{ref_sfx}",
                 _time(fused_q, x, al, sc, p["idx"], reps=reps)),
                (f"ovsf_materialize_{dt}",
                 _time(mat_q, x, al, sc, p["idx"], reps=reps))]:
            print_fn(f"kernel_bench,cpu_wall,{name},{t:.1f}us")
            rows.append(dict(kind="cpu", name=name, us=t))

    # analytical decode-shape roofline per (path, alpha dtype) on v5e
    model_ii: dict = {}
    for dt in ("", "int8", "int4"):
        for path in ("materialize", "fused", "spectral"):
            name = f"{path}_{dt}" if dt else path
            l = pm.GemmLayer("bench", M=8, d_in=4096, d_out=4096, rho=0.5,
                             ovsf=True, exec_path=path, seg=16, alpha_dtype=dt)
            t = pm.layer_timing(l)
            model_ii[name] = t.ii
            print_fn(f"kernel_bench,v5e_model,{name},ii={t.ii*1e6:.2f}us,"
                     f"bound={t.bound},mem_w={t.t_mem_w*1e6:.2f}us,"
                     f"wgen={t.t_wgen*1e6:.2f}us,eng={t.t_eng*1e6:.2f}us")
            rows.append(dict(kind="v5e", name=name, ii_us=t.ii * 1e6,
                             bound=t.bound))
    ld = pm.GemmLayer("dense", M=8, d_in=4096, d_out=4096)
    t = pm.layer_timing(ld)
    print_fn(f"kernel_bench,v5e_model,dense,ii={t.ii*1e6:.2f}us,bound={t.bound}")
    rows.append(dict(kind="v5e", name="dense", ii_us=t.ii * 1e6, bound=t.bound))

    # CI gate: quantising the stored alphas must strictly lower the modeled
    # fused II on v5e (the whole point of the alpha pipeline); int8 is always
    # checked, plus whichever dtype the caller asked for.
    for dt in {"int8", alpha_dtype} - {""}:
        if not model_ii[f"fused_{dt}"] < model_ii["fused"]:
            raise RuntimeError(
                f"modeled fused-{dt} II ({model_ii[f'fused_{dt}']*1e6:.2f}us) "
                f"is not strictly below fused-fp "
                f"({model_ii['fused']*1e6:.2f}us) on v5e")
    print_fn("kernel_bench,gate,fused_int8_ii_below_fp,ok")

    if json_path:
        payload = {"bench": "kernels", "smoke": smoke,
                   "shape": dict(M=M, d_in=d_in, d_out=d_out, rho=rho),
                   "backend": jax.default_backend(), "rows": rows}
        from repro.checkpoint.ckpt import atomic_write_json
        atomic_write_json(json_path, payload, indent=2)
        print_fn(f"kernel_bench,json,{json_path}")
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--alpha-dtype", default="", choices=["", "int8", "int4"])
    a = ap.parse_args()
    run(smoke=a.smoke, alpha_dtype=a.alpha_dtype)
